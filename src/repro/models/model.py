"""Full language-model assembly for every assigned architecture family.

One :class:`LM` wraps a ModelConfig and provides
  decls / init / specs / abstract     — parameter machinery (see param.py)
  forward(params, batch)              — training/prefill hidden states
  loss(params, batch, n_clients)      — CE + MoE aux + the paper's FDA MMD head
  decode_step(params, cache, batch)   — one-token serve step with KV/SSM cache
  init_cache / abstract_cache         — cache pytrees (concrete or ShapeDtype)

Uniform layer stacks are `lax.scan`-ned over stacked parameters (HLO size stays
O(1) in depth); the hybrid (shared attention every k SSM layers) and VLM
(cross-attention every k self layers) families run grouped scans with the
non-uniform blocks unrolled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.attention import gqa_decl, gqa_decode, gqa_forward, image_kv
from repro.models.fda_head import fda_decl, fda_loss
from repro.models.layers import (
    ShardRules,
    cross_entropy,
    embed,
    embedding_decl,
    rmsnorm,
    rmsnorm_decl,
    unembed,
)
from repro.models.param import ParamDecl, abstract, materialize, param_count, specs, stack_decls


def _tree_slice(tree, start: int, size: int):
    return jax.tree_util.tree_map(lambda a: a[start : start + size], tree)


def _tree_index(tree, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


class LM:
    def __init__(self, cfg: ModelConfig, rules: ShardRules | None = None):
        self.cfg = cfg
        self.rules = rules or ShardRules()

    def _scan(self, body, init, xs):
        """Layer scan; unrolled when cfg.unroll_scan (roofline dry-runs need
        true per-step op counts — XLA counts while bodies once)."""
        return jax.lax.scan(body, init, xs, unroll=True if self.cfg.unroll_scan else 1)

    def _sp(self, x):
        """§Perf sequence parallelism: pin the residual's seq dim sharded over
        the model axis between blocks, so XLA lowers the TP partial-sum
        all-reduces as reduce-scatter (+all-gather at the next TP einsum) —
        half the ICI bytes, and norms/elementwise work shards 16-way."""
        cfg, rules = self.cfg, self.rules
        if not (cfg.seq_parallel and getattr(rules, "mesh", None) is not None):
            return x
        from jax.sharding import NamedSharding
        bspec = rules.batch
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, P(bspec, rules.model_axis, None))
        )

    # ------------------------------------------------------------------
    # parameter declarations
    # ------------------------------------------------------------------
    def decls(self) -> dict:
        cfg, rules = self.cfg, self.rules
        d: dict[str, Any] = {}
        if not cfg.embeddings_in:
            d["embedding"] = embedding_decl(cfg, rules)
        else:
            v = cfg.vocab_padded
            d["embedding"] = {
                "unembed": ParamDecl((cfg.d_model, v), P(None, rules.tp(v)), "normal", cfg.dtype)
            }
        d["ln_f"] = rmsnorm_decl(cfg.d_model, cfg.dtype)
        d["fda"] = fda_decl(cfg)

        if cfg.family in ("dense", "moe", "audio"):
            d["blocks"] = stack_decls(B.decoder_block_decl(cfg, rules), cfg.n_layers)
        elif cfg.family == "ssm":
            d["blocks"] = stack_decls(B.ssm_block_decl(cfg, rules), cfg.n_layers)
        elif cfg.family == "hybrid":
            d["blocks"] = stack_decls(B.ssm_block_decl(cfg, rules), cfg.n_layers)
            d["shared_attn"] = {
                "ln": rmsnorm_decl(cfg.d_model, cfg.dtype),
                "attn": gqa_decl(cfg, rules),
            }
        elif cfg.family == "vlm":
            n_cross = cfg.n_layers // (cfg.cross_attn_every + 1)
            n_self = cfg.n_layers - n_cross
            d["blocks"] = stack_decls(B.decoder_block_decl(cfg, rules), n_self)
            d["cross_blocks"] = stack_decls(B.cross_block_decl(cfg, rules), n_cross)
        else:
            raise ValueError(f"unknown family {cfg.family}")
        return d

    def init(self, key: jax.Array):
        return materialize(self.decls(), key)

    def specs(self):
        return specs(self.decls())

    def abstract(self):
        return abstract(self.decls())

    def param_count(self) -> int:
        return param_count(self.decls())

    # ------------------------------------------------------------------
    # layer-group geometry for non-uniform families
    # ------------------------------------------------------------------
    def _hybrid_groups(self) -> tuple[int, int]:
        """(n_groups, remainder): shared attn applied after every group."""
        k = self.cfg.attn_every
        return self.cfg.n_layers // k, self.cfg.n_layers % k

    def _vlm_groups(self) -> tuple[int, int, int]:
        """(n_cross, self_per_group, self_remainder)."""
        n_cross = self.cfg.n_layers // (self.cfg.cross_attn_every + 1)
        n_self = self.cfg.n_layers - n_cross
        per = self.cfg.cross_attn_every
        return n_cross, per, n_self - n_cross * per

    # ------------------------------------------------------------------
    # forward (training / prefill)
    # ------------------------------------------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.embeddings_in:
            x = batch["embeddings"].astype(cfg.dtype)
        else:
            x = embed(params["embedding"], batch["tokens"])
        return x

    def forward(self, params, batch) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (hidden (b,s,d), aux_loss)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)

        if cfg.family in ("dense", "moe", "audio"):
            def body(carry, layer_params):
                y, aux = B.decoder_block_forward(
                    layer_params, carry, positions, cfg, rules=self.rules
                )
                return self._sp(y), aux

            if cfg.remat:
                body = jax.checkpoint(body)
            x, auxs = self._scan(body, self._sp(x), params["blocks"])
            return self._finish(params, x), jnp.mean(auxs)

        if cfg.family == "ssm":
            def body(carry, layer_params):
                y, aux = B.ssm_block_forward(layer_params, carry, cfg)
                return y, aux

            if cfg.remat:
                body = jax.checkpoint(body)
            x, auxs = self._scan(body, x, params["blocks"])
            return self._finish(params, x), jnp.mean(auxs)

        if cfg.family == "hybrid":
            def body(carry, layer_params):
                y, aux = B.ssm_block_forward(layer_params, carry, cfg)
                return y, aux

            if cfg.remat:
                body = jax.checkpoint(body)

            def attn_apply(h):
                z = rmsnorm(params["shared_attn"]["ln"], h, cfg.norm_eps)
                return h + gqa_forward(params["shared_attn"]["attn"], z, positions, cfg)

            if cfg.remat:
                attn_apply = jax.checkpoint(attn_apply)
            ng, rem = self._hybrid_groups()
            k = cfg.attn_every
            for g in range(ng):
                x, _ = self._scan(body, x, _tree_slice(params["blocks"], g * k, k))
                x = attn_apply(x)
            if rem:
                x, _ = self._scan(body, x, _tree_slice(params["blocks"], ng * k, rem))
            return self._finish(params, x), jnp.zeros((), jnp.float32)

        if cfg.family == "vlm":
            def body(carry, layer_params):
                y, aux = B.decoder_block_forward(
                    layer_params, carry, positions, cfg, rules=self.rules
                )
                return y, aux

            if cfg.remat:
                body = jax.checkpoint(body)
            img = batch["images"].astype(cfg.dtype)  # (b, n_img, d_image)
            n_cross, per, rem = self._vlm_groups()
            for g in range(n_cross):
                x, _ = self._scan(body, x, _tree_slice(params["blocks"], g * per, per))
                cp = _tree_index(params["cross_blocks"], g)

                def xbody(h):
                    kv = image_kv(cp["xattn"], img)
                    return B.cross_block_forward(cp, h, kv, cfg)

                x = jax.checkpoint(xbody)(x) if cfg.remat else xbody(x)
            if rem:
                x, _ = self._scan(body, x, _tree_slice(params["blocks"], n_cross * per, rem))
            return self._finish(params, x), jnp.zeros((), jnp.float32)

        raise ValueError(cfg.family)

    # ------------------------------------------------------------------
    # prefill: forward + KV/SSM cache collection for the decode handoff
    # ------------------------------------------------------------------
    def prefill(self, params, batch):
        """Returns (last-token logits (b, vocab_padded), cache)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)

        if cfg.family in ("dense", "moe", "audio", "ssm"):
            def body(carry, layer_params):
                if cfg.family == "ssm":
                    y, _, cache = B.ssm_block_forward(layer_params, carry, cfg, collect_cache=True)
                else:
                    y, _, cache = B.decoder_block_forward(
                        layer_params, carry, positions, cfg, collect_cache=True,
                        rules=self.rules,
                    )
                return y, cache

            if cfg.remat:
                body = jax.checkpoint(body)
            x, layers = self._scan(body, x, params["blocks"])
            return self._last_logits(params, x), {"layers": layers}

        if cfg.family == "hybrid":
            def body(carry, layer_params):
                y, _, cache = B.ssm_block_forward(layer_params, carry, cfg, collect_cache=True)
                return y, cache

            if cfg.remat:
                body = jax.checkpoint(body)
            ng, rem = self._hybrid_groups()
            k = cfg.attn_every
            layer_caches, ak, av = [], [], []
            for g in range(ng):
                x, lc = self._scan(body, x, _tree_slice(params["blocks"], g * k, k))
                layer_caches.append(lc)
                h = rmsnorm(params["shared_attn"]["ln"], x, cfg.norm_eps)
                o, (kk, vv) = gqa_forward(
                    params["shared_attn"]["attn"], h, positions, cfg, return_kv=True
                )
                x = x + o
                ak.append(kk)
                av.append(vv)
            if rem:
                x, lc = self._scan(body, x, _tree_slice(params["blocks"], ng * k, rem))
                layer_caches.append(lc)
            cache = {
                "layers": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *layer_caches),
                "attn_k": jnp.stack(ak),
                "attn_v": jnp.stack(av),
            }
            return self._last_logits(params, x), cache

        if cfg.family == "vlm":
            def body(carry, layer_params):
                y, _, cache = B.decoder_block_forward(
                    layer_params, carry, positions, cfg, collect_cache=True, rules=self.rules
                )
                return y, cache

            if cfg.remat:
                body = jax.checkpoint(body)
            img = batch["images"].astype(cfg.dtype)
            n_cross, per, rem = self._vlm_groups()
            layer_caches, ik, iv = [], [], []
            for g in range(n_cross):
                x, lc = self._scan(body, x, _tree_slice(params["blocks"], g * per, per))
                layer_caches.append(lc)
                cp = _tree_index(params["cross_blocks"], g)
                kv = image_kv(cp["xattn"], img)
                x = B.cross_block_forward(cp, x, kv, cfg)
                ik.append(kv[0])
                iv.append(kv[1])
            if rem:
                x, lc = self._scan(body, x, _tree_slice(params["blocks"], n_cross * per, rem))
                layer_caches.append(lc)
            cache = {
                "layers": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *layer_caches),
                "img_k": jnp.stack(ik),
                "img_v": jnp.stack(iv),
            }
            return self._last_logits(params, x), cache

        raise ValueError(cfg.family)

    def _last_logits(self, params, x):
        x = self._finish(params, x[:, -1:, :])
        return self.logits(params, x)[:, 0, :]

    def _finish(self, params, x):
        return rmsnorm(params["ln_f"], x, self.cfg.norm_eps)

    def logits(self, params, hidden):
        return unembed(params["embedding"], hidden)

    # ------------------------------------------------------------------
    # training loss: CE + MoE aux + the paper's FDA MMD head
    # ------------------------------------------------------------------
    def loss(self, params, batch, n_clients: int = 1):
        cfg = self.cfg
        hidden, aux = self.forward(params, batch)
        logits = self.logits(params, hidden)
        ce = cross_entropy(logits, batch["labels"], cfg.vocab_size, sharded=cfg.sharded_ce)
        total = ce + 0.01 * aux
        mmd = jnp.zeros((), jnp.float32)
        if cfg.fda_lambda and n_clients > 1:
            mmd = fda_loss(params["fda"], hidden, n_clients)
            total = total + cfg.fda_lambda * mmd
        return total, {"ce": ce, "aux": aux, "mmd": mmd}

    # ------------------------------------------------------------------
    # decode (serve) path
    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, s_cache: int) -> dict:
        cfg = self.cfg
        if cfg.attn_window:
            s_cache = min(s_cache, cfg.attn_window)
        if cfg.family in ("dense", "moe", "audio"):
            per = B.decoder_cache_decl(cfg, batch, s_cache)
            return {"layers": {k: (cfg.n_layers, *v) for k, v in per.items()}}
        if cfg.family == "ssm":
            per = B.ssm_cache_decl(cfg, batch)
            return {"layers": {k: (cfg.n_layers, *v) for k, v in per.items()}}
        if cfg.family == "hybrid":
            per = B.ssm_cache_decl(cfg, batch)
            ng, _ = self._hybrid_groups()
            return {
                "layers": {k: (cfg.n_layers, *v) for k, v in per.items()},
                "attn_k": (ng, batch, s_cache, cfg.n_kv_heads, cfg.hd),
                "attn_v": (ng, batch, s_cache, cfg.n_kv_heads, cfg.hd),
            }
        if cfg.family == "vlm":
            n_cross, _, _ = self._vlm_groups()
            n_self = cfg.n_layers - n_cross
            per = B.decoder_cache_decl(cfg, batch, s_cache)
            return {
                "layers": {k: (n_self, *v) for k, v in per.items()},
                "img_k": (n_cross, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.hd),
                "img_v": (n_cross, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.hd),
            }
        raise ValueError(cfg.family)

    def _cache_tree(self, shapes, maker):
        return jax.tree_util.tree_map(maker, shapes, is_leaf=lambda x: isinstance(x, tuple))

    def init_cache(self, batch: int, s_cache: int):
        return self._cache_tree(
            self.cache_shapes(batch, s_cache), lambda s: jnp.zeros(s, self.cfg.dtype)
        )

    def abstract_cache(self, batch: int, s_cache: int):
        return self._cache_tree(
            self.cache_shapes(batch, s_cache),
            lambda s: jax.ShapeDtypeStruct(s, self.cfg.dtype),
        )

    def cache_specs(self):
        """Batch dim of every cache leaf is data-sharded."""
        def spec(shape):
            return P(None, self.rules.batch, *([None] * (len(shape) - 2)))

        return self._cache_tree(self.cache_shapes(1, 1), spec)

    def decode_step(self, params, cache, batch, pos):
        """One token for the whole stack. batch: tokens (b,1) or embeddings
        (b,1,d). pos: scalar int32 (same position across the batch).
        Returns (logits (b, vocab_padded), new_cache)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)

        if cfg.family in ("dense", "moe", "audio", "ssm"):
            def body(carry, xs):
                layer_params, layer_cache = xs
                if cfg.family == "ssm":
                    y, c = B.ssm_block_decode(layer_params, carry, layer_cache, cfg)
                else:
                    y, c = B.decoder_block_decode(
                        layer_params, carry, layer_cache, pos, cfg, rules=self.rules
                    )
                return y, c

            x, new_layers = self._scan(body, x, (params["blocks"], cache["layers"]))
            cache = {**cache, "layers": new_layers}
            return self._decode_logits(params, x), cache

        if cfg.family == "hybrid":
            def body(carry, xs):
                layer_params, layer_cache = xs
                return B.ssm_block_decode(layer_params, carry, layer_cache, cfg)

            ng, rem = self._hybrid_groups()
            k = cfg.attn_every
            new_layers = []
            new_ak, new_av = [], []
            for g in range(ng):
                x, nl = self._scan(
                    body, x, (_tree_slice(params["blocks"], g * k, k),
                              _tree_slice(cache["layers"], g * k, k))
                )
                new_layers.append(nl)
                h = rmsnorm(params["shared_attn"]["ln"], x, cfg.norm_eps)
                o, ck, cv = gqa_decode(
                    params["shared_attn"]["attn"], h, cache["attn_k"][g], cache["attn_v"][g],
                    pos, cfg,
                )
                x = x + o
                new_ak.append(ck)
                new_av.append(cv)
            if rem:
                x, nl = self._scan(
                    body, x, (_tree_slice(params["blocks"], ng * k, rem),
                              _tree_slice(cache["layers"], ng * k, rem))
                )
                new_layers.append(nl)
            cache = {
                "layers": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *new_layers),
                "attn_k": jnp.stack(new_ak),
                "attn_v": jnp.stack(new_av),
            }
            return self._decode_logits(params, x), cache

        if cfg.family == "vlm":
            def body(carry, xs):
                layer_params, layer_cache = xs
                return B.decoder_block_decode(
                    layer_params, carry, layer_cache, pos, cfg, rules=self.rules
                )

            n_cross, per, rem = self._vlm_groups()
            new_layers = []
            for g in range(n_cross):
                x, nl = self._scan(
                    body, x, (_tree_slice(params["blocks"], g * per, per),
                              _tree_slice(cache["layers"], g * per, per))
                )
                new_layers.append(nl)
                cp = _tree_index(params["cross_blocks"], g)
                kv = (cache["img_k"][g], cache["img_v"][g])
                x = B.cross_block_forward(cp, x, kv, cfg)
            if rem:
                x, nl = self._scan(
                    body, x, (_tree_slice(params["blocks"], n_cross * per, rem),
                              _tree_slice(cache["layers"], n_cross * per, rem))
                )
                new_layers.append(nl)
            cache = {
                **cache,
                "layers": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *new_layers),
            }
            return self._decode_logits(params, x), cache

        raise ValueError(cfg.family)

    def _decode_logits(self, params, x):
        x = self._finish(params, x)
        return self.logits(params, x)[:, 0, :]
