"""The paper's technique as a first-class backbone head.

Pooled final hidden states -> fixed shared-seed RFF compressor -> trainable
linear aligner W_RF -> decomposable MMD loss across clients (paper eq. 11).

On the production mesh the client axis IS the data-parallel axis: the batch is
laid out as (n_clients, per_client, ...) and the only cross-client traffic the
loss induces is the mean of the (n_clients, 2N) message matrix — an all-reduce
of 2N floats per step, the paper's O(KN) claim, visible in the dry-run HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.param import ParamDecl


def fda_decl(cfg: ModelConfig) -> dict:
    n = cfg.fda_n_rff
    return {
        # fixed compressor: shared-seed Omega (stop-gradient in the loss);
        # std ~ 2 on unit-normalised pooled features
        "omega": ParamDecl((n, cfg.d_model), P(None, None), "std", jnp.float32, scale=2.0),
        "w_rf": ParamDecl((2 * n, cfg.fda_m), P(None, None), "normal", jnp.float32),
    }


def fda_messages(params, hidden: jnp.ndarray, n_clients: int) -> jnp.ndarray:
    """Per-client compressed messages Sigma ell: (n_clients, 2N)."""
    b = hidden.shape[0]
    pooled = jnp.mean(hidden.astype(jnp.float32), axis=1)  # (b, d)
    pooled = pooled / (jnp.linalg.norm(pooled, axis=-1, keepdims=True) + 1e-6)
    omega = jax.lax.stop_gradient(params["omega"])
    z = pooled @ omega.T  # (b, N)
    n = omega.shape[0]
    feats = jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=-1) / jnp.sqrt(n)  # (b, 2N)
    return feats.reshape(n_clients, b // n_clients, 2 * n).mean(axis=1)


def fda_loss(params, hidden: jnp.ndarray, n_clients: int) -> jnp.ndarray:
    """Align every client's mean embedding to the federation mean (eq. 11 with
    the global mean as the target message)."""
    msgs = fda_messages(params, hidden, n_clients)
    center = jnp.mean(msgs, axis=0)  # the 2N-float all-reduce
    v = (msgs - center[None, :]) @ params["w_rf"]  # (nc, m)
    return jnp.mean(jnp.sum(v * v, axis=-1))
