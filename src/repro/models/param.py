"""Single-source-of-truth parameter declarations.

Every module declares its parameters as a pytree of :class:`ParamDecl`; the
same tree then yields

- concrete parameters          (:func:`materialize`, seeded per-path),
- ``PartitionSpec`` tree       (:func:`specs`) for pjit in/out shardings,
- ``ShapeDtypeStruct`` tree    (:func:`abstract`) for the AOT dry-run,

so shapes, shardings, and init can never drift apart.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDecl:
    shape: tuple[int, ...]
    spec: Any  # PartitionSpec
    init: str = "normal"  # "normal" | "zeros" | "ones" | "scaled:<fan_in>"
    dtype: Any = jnp.float32
    scale: float = 1.0

    def stacked(self, n: int, stack_spec_axis=None) -> "ParamDecl":
        """Prepend a layer axis (for lax.scan over stacked blocks)."""
        spec = P(stack_spec_axis, *self.spec) if self.spec is not None else None
        return ParamDecl((n, *self.shape), spec, self.init, self.dtype, self.scale)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _tree_map(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_decl)


def materialize(decls, key: jax.Array, path: str = ""):
    """Instantiate parameters; each leaf key is derived from its tree path so
    results are independent of traversal order."""
    flat = jax.tree_util.tree_flatten_with_path(decls, is_leaf=is_decl)[0]
    treedef = jax.tree_util.tree_structure(decls, is_leaf=is_decl)
    leaves = []
    for kp, d in flat:
        pathstr = path + jax.tree_util.keystr(kp)
        digest = int.from_bytes(hashlib.sha256(pathstr.encode()).digest()[:4], "big")
        k = jax.random.fold_in(key, digest)
        if d.init == "zeros":
            leaves.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            leaves.append(jnp.ones(d.shape, d.dtype))
        elif d.init == "normal":
            fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            std = d.scale / np.sqrt(fan_in)
            leaves.append((jax.random.normal(k, d.shape) * std).astype(d.dtype))
        elif d.init == "std":
            # direct standard deviation (scale IS the std)
            leaves.append((jax.random.normal(k, d.shape) * d.scale).astype(d.dtype))
        elif d.init == "ssm_a":
            # mamba2 A init: A = -exp(a_log), a ~ U[1, 16]
            a = jax.random.uniform(k, d.shape, minval=1.0, maxval=16.0)
            leaves.append(jnp.log(a).astype(d.dtype))
        elif d.init == "ssm_dt":
            # dt bias: softplus^-1 of U[1e-3, 1e-1]
            dt = jax.random.uniform(k, d.shape, minval=1e-3, maxval=1e-1)
            leaves.append(jnp.log(jnp.expm1(dt)).astype(d.dtype))
        else:
            raise ValueError(f"unknown init {d.init}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def specs(decls):
    """PartitionSpec pytree with the same structure as the parameters."""
    return _tree_map(lambda d: d.spec if d.spec is not None else P(), decls)


def abstract(decls):
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    return _tree_map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls)


def stack_decls(decls, n: int):
    """Stack every decl with a leading layer axis (for scanned blocks)."""
    return _tree_map(lambda d: d.stacked(n), decls)


def param_count(decls) -> int:
    return int(sum(np.prod(d.shape) for d in jax.tree_util.tree_leaves(decls, is_leaf=is_decl)))
