"""Mixture-of-Experts with top-k routing and capacity-bounded scatter dispatch.

TPU-native dispatch: tokens are scatter-added into a per-expert buffer
(E, C, d) — no (T, E, C) one-hot dispatch tensor is ever materialised — then a
single batched einsum runs all experts, and results gather back weighted by
the (renormalised) router probabilities. Expert weights shard over the
``model`` mesh axis (expert parallelism); XLA inserts the token all-to-alls.

Supports DeepSeek-style shared experts (always-on dense experts alongside the
routed ones) and emits the standard load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ShardRules, mlp, mlp_decl
from repro.models.param import ParamDecl


def moe_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    e_spec = rules.tp(e)
    decl = {
        "router": ParamDecl((d, e), P(None, None), "normal", jnp.float32),
        "gate": ParamDecl((e, d, f), P(e_spec, None, None), "normal", cfg.dtype),
        "up": ParamDecl((e, d, f), P(e_spec, None, None), "normal", cfg.dtype),
        "down": ParamDecl((e, f, d), P(e_spec, None, None), "normal", cfg.dtype),
    }
    if cfg.n_shared_experts:
        decl["shared"] = mlp_decl(cfg, rules, d_ff=cfg.n_shared_experts * cfg.d_ff)
    return decl


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)


def moe_forward(params, x: jnp.ndarray, cfg: ModelConfig):
    """x: (b, s, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise over chosen

    # load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    c = capacity(cfg, t)
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t), k)

    # position of each (token, choice) within its expert's buffer
    one_hot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(one_hot, axis=0) - 1
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < c
    slot = jnp.where(keep, flat_e * c + pos, e * c)  # overflow slot dropped

    buf = jnp.zeros((e * c + 1, d), cfg.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[tok_idx], 0))
    buf = buf[:-1].reshape(e, c, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, params["up"]
    )
    out = jnp.einsum("ecf,efd->ecd", h, params["down"]).reshape(e * c, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)])  # overflow reads 0

    gathered = out[slot] * (flat_p * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), cfg.dtype).at[tok_idx].add(gathered)

    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], xt)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# expert-parallel dispatch (shard_map) — §Perf hillclimb
# ---------------------------------------------------------------------------
#
# The baseline scatter above is written on GLOBAL shapes; its data-dependent
# scatter indices block the SPMD partitioner, so XLA replicates the dispatch
# and every chip computes (up to) the full global expert batch. Here the
# routing is made explicitly local: each (data, model) shard routes ITS tokens
# to ITS E/M experts and the only cross-chip combine is one psum of the
# (b_loc, s, d) output over the model axis — the same all-reduce tensor
# parallelism already pays for the dense layers.

def moe_forward_ep(params, x: jnp.ndarray, cfg: ModelConfig, rules: ShardRules):
    """Expert-parallel MoE. x: (b, s, d) with batch sharded over rules.batch,
    expert weights sharded over rules.model_axis. Requires rules.mesh."""
    from jax.experimental.shard_map import shard_map

    mesh = rules.mesh
    m_axis = rules.model_axis
    e_total = cfg.n_experts
    m_size = mesh.shape[m_axis]
    e_loc = e_total // m_size
    all_axes = tuple(rules.batch_axes) + (m_axis,)

    def local(x_loc, router, gate_w, up_w, down_w):
        b_loc, s, d = x_loc.shape
        t = b_loc * s
        k = cfg.top_k
        xt = x_loc.reshape(t, d)
        logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        ce_cnt = jnp.zeros((e_total,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
        aux = e_total * jnp.sum(me * ce_cnt)
        # x is replicated over the model axis, so aux only varies over batch
        aux = jax.lax.pmean(aux, tuple(rules.batch_axes))

        c = capacity(cfg, t)
        flat_e = top_e.reshape(-1)
        flat_p = top_p.reshape(-1)
        tok_idx = jnp.repeat(jnp.arange(t), k)
        one_hot = jax.nn.one_hot(flat_e, e_total, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(one_hot, axis=0) - 1
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]

        # local experts on this model shard: [m_idx*e_loc, (m_idx+1)*e_loc)
        m_idx = jax.lax.axis_index(m_axis)
        local_e = flat_e - m_idx * e_loc
        keep = (pos < c) & (local_e >= 0) & (local_e < e_loc)
        slot = jnp.where(keep, local_e * c + pos, e_loc * c)

        buf = jnp.zeros((e_loc * c + 1, d), cfg.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xt[tok_idx], 0))
        buf = buf[:-1].reshape(e_loc, c, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w)) * jnp.einsum(
            "ecd,edf->ecf", buf, up_w
        )
        out = jnp.einsum("ecf,efd->ecd", h, down_w).reshape(e_loc * c, d)
        out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)])
        gathered = out[slot] * (flat_p * keep)[:, None].astype(out.dtype)
        y = jnp.zeros((t, d), cfg.dtype).at[tok_idx].add(gathered)
        # combine contributions from all expert shards
        y = jax.lax.psum(y, m_axis)
        return y.reshape(b_loc, s, d), aux

    from jax.sharding import PartitionSpec as P

    bspec = rules.batch
    y, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P(m_axis, None, None),
            P(m_axis, None, None),
            P(m_axis, None, None),
        ),
        out_specs=(P(bspec, None, None), P()),
    )(x, params["router"], params["gate"], params["up"], params["down"])
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x.reshape(-1, x.shape[-1])).reshape(x.shape)
    return y, aux
