"""Attention: GQA with blockwise (flash-style) softmax, sliding window,
DeepSeek MLA (kv-LoRA with decoupled RoPE + absorbed decode), cross-attention.

Training/prefill attention is a double-blocked online-softmax scan (the same
math as the Pallas kernel in repro.kernels.flash_attention — that kernel is the
TPU hot-spot implementation, this is the XLA-composable form used inside
scanned layers). Decode is a single-token einsum against the KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ShardRules, apply_rope
from repro.models.param import ParamDecl

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter declarations
# ---------------------------------------------------------------------------

def gqa_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h_spec, kv_spec = rules.tp(h), rules.tp(kv)
    return {
        "wq": ParamDecl((d, h, hd), P(None, h_spec, None), "normal", cfg.dtype),
        "wk": ParamDecl((d, kv, hd), P(None, kv_spec, None), "normal", cfg.dtype),
        "wv": ParamDecl((d, kv, hd), P(None, kv_spec, None), "normal", cfg.dtype),
        "wo": ParamDecl((h, hd, d), P(h_spec, None, None), "normal", cfg.dtype),
    }


def mla_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    h_spec = rules.tp(h)
    return {
        "wq_nope": ParamDecl((d, h, hd), P(None, h_spec, None), "normal", cfg.dtype),
        "wq_rope": ParamDecl((d, h, rd), P(None, h_spec, None), "normal", cfg.dtype),
        "w_dkv": ParamDecl((d, r), P(None, None), "normal", cfg.dtype),
        "w_krope": ParamDecl((d, rd), P(None, None), "normal", cfg.dtype),
        "w_uk": ParamDecl((r, h, hd), P(None, h_spec, None), "normal", cfg.dtype),
        "w_uv": ParamDecl((r, h, hd), P(None, h_spec, None), "normal", cfg.dtype),
        "wo": ParamDecl((h, hd, d), P(h_spec, None, None), "normal", cfg.dtype),
    }


def cross_attn_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h_spec, kv_spec = rules.tp(h), rules.tp(kv)
    return {
        "wq": ParamDecl((d, h, hd), P(None, h_spec, None), "normal", cfg.dtype),
        "wk": ParamDecl((cfg.d_image, kv, hd), P(None, kv_spec, None), "normal", cfg.dtype),
        "wv": ParamDecl((cfg.d_image, kv, hd), P(None, kv_spec, None), "normal", cfg.dtype),
        "wo": ParamDecl((h, hd, d), P(h_spec, None, None), "normal", cfg.dtype),
        "gate": ParamDecl((), P(), "zeros", cfg.dtype),  # zero-init gated residual
    }


# ---------------------------------------------------------------------------
# blockwise online-softmax attention (training / prefill)
# ---------------------------------------------------------------------------

def _block_sizes(s: int) -> tuple[int, int]:
    # 4096 keeps HLO block counts small at 32k+ sequences (the XLA-composable
    # flash relies on fusion, not VMEM tiling — that's the Pallas kernel's job)
    bq = min(s, 4096)
    bk = min(s, 4096)
    # make them divide s (shapes here are powers of two)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    return max(bq, 1), max(bk, 1)


def flash_attention(
    q: jnp.ndarray,  # (b, s, h, hd)
    k: jnp.ndarray,  # (b, s, kv, hd)
    v: jnp.ndarray,  # (b, s, kv, hd)
    *,
    causal: bool = True,
    window: int = 0,  # sliding window (0 = unlimited)
    unroll: bool = False,  # roofline dry-runs: XLA counts while bodies once
    skip_masked: bool = False,  # §Perf: triangular causal schedule
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]  # may differ from hd (MLA: qk dim = nope+rope, v dim = hd)
    g = h // kv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    bq, bk = _block_sizes(s)
    nq, nk = s // bq, s // bk

    qb = q.reshape(b, nq, bq, kv, g, hd)
    kb = k.reshape(b, nk, bk, kv, hd)
    vb = v.reshape(b, nk, bk, kv, vd)

    q_pos = jnp.arange(s).reshape(nq, bq)
    k_pos = jnp.arange(s).reshape(nk, bk)

    def make_kv_block(qx, qp):
        def kv_block(state, ki):
            acc, m, lse = state
            kx, vx, kp = ki  # (b, bk, kv, hd), (b, bk, kv, hd), (bk,)
            sc = jnp.einsum(
                "bqkgd,bskd->bqkgs", qx, kx, preferred_element_type=jnp.float32
            ) * scale
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * lse + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(vx.dtype), vx,
                            preferred_element_type=jnp.float32)
            acc_new = corr[..., None] * acc + pv
            return (acc_new, m_new, l_new), None

        return kv_block

    def init_state():
        return (
            jnp.zeros((b, bq, kv, g, vd), jnp.float32),
            jnp.full((b, bq, kv, g), NEG_INF, jnp.float32),
            jnp.zeros((b, bq, kv, g), jnp.float32),
        )

    kt = kb.transpose(1, 0, 2, 3, 4)
    vt = vb.transpose(1, 0, 2, 3, 4)

    if skip_masked and causal:
        # §Perf: triangular schedule — only kv blocks that intersect the mask
        # are computed. Halves attention FLOPs vs the masked-full baseline.
        qt = qb.transpose(1, 0, 2, 3, 4, 5)
        outs = []
        for qi in range(nq):
            hi = min(nk, (qi + 1) * bq // bk + (1 if ((qi + 1) * bq) % bk else 0))
            lo = max(0, (qi * bq - window + 1) // bk) if window else 0
            kv_fn = make_kv_block(qt[qi], q_pos[qi])
            (acc, m, lse), _ = jax.lax.scan(
                kv_fn, init_state(), (kt[lo:hi], vt[lo:hi], k_pos[lo:hi]),
                unroll=True if unroll else 1,
            )
            out = acc / jnp.maximum(lse[..., None], 1e-30)
            outs.append(out.astype(q.dtype))
        ob = jnp.stack(outs)
        return ob.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, vd)

    def q_block(carry, qi):
        qx, qp = qi  # (b, bq, kv, g, hd), (bq,)
        kv_fn = make_kv_block(qx, qp)
        (acc, m, lse), _ = jax.lax.scan(
            kv_fn, init_state(), (kt, vt, k_pos), unroll=True if unroll else 1
        )
        out = acc / jnp.maximum(lse[..., None], 1e-30)
        return carry, out.astype(q.dtype)

    _, ob = jax.lax.scan(
        q_block, None, (qb.transpose(1, 0, 2, 3, 4, 5), q_pos), unroll=True if unroll else 1
    )
    # ob: (nq, b, bq, kv, g, vd) -> (b, s, h, vd)
    return ob.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, vd)


# ---------------------------------------------------------------------------
# GQA self-attention block bodies
# ---------------------------------------------------------------------------

def gqa_forward(
    params, x, positions, cfg: ModelConfig, *, window: int | None = None, return_kv: bool = False
):
    """Training/prefill path. x: (b, s, d). With return_kv, also returns the
    roped (k, v) so prefill can hand the cache to decode."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.attn_window if window is None else window
    o = flash_attention(
        q, k, v, causal=True, window=w, unroll=cfg.unroll_scan, skip_masked=cfg.causal_skip
    )
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(params, x, cache_k, cache_v, pos, cfg: ModelConfig, *, window: int | None = None,
               rules=None):
    """Single-token decode. x: (b, 1, d); cache: (b, S, kv, hd); pos: scalar.

    With a sliding window the cache is a ring buffer of size S=window.
    Returns (out (b,1,d), cache_k, cache_v).

    §Perf note: when q heads are model-sharded but kv heads are NOT divisible
    by the model axis, the (kv, g) reshape propagates a partial head sharding
    onto the KV cache and XLA re-shards (all-gathers) the entire cache every
    step — measured at ~2.1GB/layer/step for internlm2 decode_32k. When a
    mesh is available (rules.mesh) we pin q replicated over the model axis:
    decode attention FLOPs are negligible, the cache never moves.
    """
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, jnp.full((1,), pos), cfg.rope_theta)
    k = apply_rope(k, jnp.full((1,), pos), cfg.rope_theta)
    slot = pos % s_cache  # ring buffer when s_cache == window
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    kv = cache_k.shape[2]
    g = q.shape[2] // kv
    qg = q.reshape(b, 1, kv, g, q.shape[-1])
    if rules is not None and getattr(rules, "mesh", None) is not None and kv % rules.model_size:
        from jax.sharding import NamedSharding

        bspec = rules.batch if b % 16 == 0 else None
        qg = jax.lax.with_sharding_constraint(
            qg, NamedSharding(rules.mesh, P(bspec, None, None, None, None))
        )
    sc = jnp.einsum("bqkgd,bskd->bqkgs", qg, cache_k, preferred_element_type=jnp.float32)
    sc = sc / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    # valid cache slots: those already written. Once the ring buffer wraps
    # (pos >= s_cache) every slot holds one of the last s_cache tokens.
    idx = jnp.arange(s_cache)
    valid = (idx <= pos) | (pos >= s_cache)
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, cache_v).reshape(b, 1, q.shape[2], q.shape[-1])
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed kv cache + decoupled rope, absorbed decode
# ---------------------------------------------------------------------------

def mla_forward(params, x, positions, cfg: ModelConfig, *, return_cache: bool = False):
    q_nope = jnp.einsum("bsd,dhk->bshk", x, params["wq_nope"])
    q_rope = jnp.einsum("bsd,dhk->bshk", x, params["wq_rope"])
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = x @ params["w_dkv"]  # (b, s, r)
    k_rope = apply_rope(
        (x @ params["w_krope"])[:, :, None, :], positions, cfg.rope_theta
    )  # (b, s, 1, rd)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope, (*k_nope.shape[:3], k_rope.shape[-1]))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = flash_attention(q, k, v, causal=True, unroll=cfg.unroll_scan, skip_masked=cfg.causal_skip)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if return_cache:
        return out, (c_kv, k_rope[:, :, 0, :])
    return out


def mla_decode(params, x, cache_c, cache_kr, pos, cfg: ModelConfig):
    """Absorbed decode: scores live in the r-dim latent space; the per-token
    cache is only (r + rope_dim) floats — MLA's memory win, visible in the
    decode roofline. cache_c: (b, S, r); cache_kr: (b, S, rd)."""
    b = x.shape[0]
    q_nope = jnp.einsum("bsd,dhk->bshk", x, params["wq_nope"])
    q_rope = jnp.einsum("bsd,dhk->bshk", x, params["wq_rope"])
    q_rope = apply_rope(q_rope, jnp.full((1,), pos), cfg.rope_theta)
    c_new = x @ params["w_dkv"]  # (b, 1, r)
    kr_new = apply_rope(
        (x @ params["w_krope"])[:, :, None, :], jnp.full((1,), pos), cfg.rope_theta
    )[:, :, 0, :]
    cache_c = jax.lax.dynamic_update_slice(cache_c, c_new.astype(cache_c.dtype), (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(cache_kr, kr_new.astype(cache_kr.dtype), (0, pos, 0))
    # absorb W_uk into q: (b,1,h,hd) x (r,h,hd) -> (b,1,h,r)
    q_eff = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["w_uk"])
    sc = jnp.einsum("bqhr,bsr->bqhs", q_eff, cache_c, preferred_element_type=jnp.float32)
    sc += jnp.einsum("bqhk,bsk->bqhs", q_rope, cache_kr, preferred_element_type=jnp.float32)
    sc = sc / jnp.sqrt(cfg.hd + cfg.rope_head_dim).astype(jnp.float32)
    valid = jnp.arange(cache_c.shape[1]) <= pos
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(cache_c.dtype)
    ctx = jnp.einsum("bqhs,bsr->bqhr", p, cache_c)  # (b,1,h,r)
    o = jnp.einsum("bqhr,rhk->bqhk", ctx, params["w_uv"])
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"]), cache_c, cache_kr


# ---------------------------------------------------------------------------
# cross-attention (VLM): text queries attend to image embeddings
# ---------------------------------------------------------------------------

def cross_attn_forward(params, x, img_kv: tuple[jnp.ndarray, jnp.ndarray], cfg: ModelConfig):
    """x: (b, s, d); img_kv: precomputed (k, v) each (b, n_img, kv, hd)."""
    k, v = img_kv
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    kvh = k.shape[2]
    g = q.shape[2] // kvh
    qg = q.reshape(b, s, kvh, g, q.shape[-1])
    sc = jnp.einsum("bqkgd,bskd->bqkgs", qg, k, preferred_element_type=jnp.float32)
    sc = sc / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    p = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v).reshape(b, s, q.shape[2], q.shape[-1])
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return jnp.tanh(params["gate"]).astype(x.dtype) * out


def image_kv(params, img_emb: jnp.ndarray):
    """Project image embeddings once: (b, n_img, d_image) -> (k, v)."""
    k = jnp.einsum("bsd,dhk->bshk", img_emb, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", img_emb, params["wv"])
    return k, v
