"""Shared model primitives: sharding rules, RMSNorm, RoPE, GLU-MLP, embeddings.

All parameter trees are declared via :mod:`repro.models.param` so shapes,
shardings and init stay in lockstep. Activations are computed in the config
dtype; norms/softmax accumulate in fp32.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.param import ParamDecl


@dataclass(frozen=True, eq=False)
class ShardRules:
    """Maps logical dimensions to mesh axes, with divisibility fallbacks."""

    model_size: int = 16  # size of the tensor-parallel mesh axis
    batch_axes: tuple[str, ...] = ("data",)  # ("pod","data") for multi-pod
    model_axis: str = "model"
    mesh: object = None  # concrete Mesh — required only by shard_map paths (moe_ep)

    def tp(self, dim: int):
        """Tensor-parallel shard `dim` if divisible, else replicate."""
        return self.model_axis if dim % self.model_size == 0 else None

    @property
    def batch(self):
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_decl(d: int, dtype) -> dict:
    return {"scale": ParamDecl((d,), P(None), "ones", dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GLU MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_decl(cfg: ModelConfig, rules: ShardRules, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ff_spec = rules.tp(f)
    return {
        "gate": ParamDecl((d, f), P(None, ff_spec), "normal", cfg.dtype),
        "up": ParamDecl((d, f), P(None, ff_spec), "normal", cfg.dtype),
        "down": ParamDecl((f, d), P(ff_spec, None), "normal", cfg.dtype),
    }


def mlp(params, x):
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


# ---------------------------------------------------------------------------
# token embedding + LM head
# ---------------------------------------------------------------------------

def embedding_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    v, d = cfg.vocab_padded, cfg.d_model
    return {
        # embed sharded along d_model: row gather stays local, small all-gather
        "embed": ParamDecl((v, d), P(None, rules.tp(d)), "normal", cfg.dtype),
        # unembed sharded along vocab: logits stay sharded through the CE loss
        "unembed": ParamDecl((d, v), P(None, rules.tp(v)), "normal", cfg.dtype),
    }


def embed(params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params, x):
    return x @ params["unembed"]


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int, *, sharded: bool = False
) -> jnp.ndarray:
    """Mean next-token CE over the real (unpadded) vocabulary.

    sharded=False (baseline): f32 cast + pad-concat + take_along_axis. The
    gather along a vocab-sharded logits axis forces XLA to ALL-GATHER the full
    (b, s, vocab) logits — measured as the dominant collective for the
    large-vocab archs (see EXPERIMENTS.md §Perf).

    sharded=True (optimized): everything is elementwise ops + reductions over
    the vocab axis, which SPMD partitions locally with only (b, s)-sized
    cross-shard reductions; the gold logit is picked with an iota==label mask
    fused into the reduce instead of a gather. Identical math.
    """
    if not sharded:
        logits = logits.astype(jnp.float32)
        pad = logits.shape[-1] - vocab_size
        if pad:
            neg = jnp.full((pad,), -1e9, dtype=logits.dtype)
            logits = logits + jnp.concatenate([jnp.zeros((vocab_size,), logits.dtype), neg])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    v_padded = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (v_padded,), 0)
    valid = iota < vocab_size  # mask padded vocab entries
    x = jnp.where(valid, logits.astype(jnp.float32), -jnp.inf)
    m = jnp.max(x, axis=-1, keepdims=True)  # local max + tiny (b,s) all-reduce
    sumexp = jnp.sum(jnp.where(valid, jnp.exp(x - m), 0.0), axis=-1)
    logz = jnp.log(sumexp) + m[..., 0]
    gold_mask = iota[None, None, :] == labels[..., None]
    gold = jnp.sum(jnp.where(gold_mask, x, 0.0), axis=-1)  # masked reduce, no gather
    return jnp.mean(logz - gold)
