from repro.models.layers import ShardRules
from repro.models.model import LM
