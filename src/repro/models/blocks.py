"""Transformer/SSM block bodies: decls + apply for every assigned family.

Each block is (decl_fn, forward_fn, decode_fn) over a params dict; model.py
stacks uniform blocks and scans them, and slices grouped stacks for the
non-uniform families (hybrid shared-attention, VLM cross-attention).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ShardRules, mlp, mlp_decl, rmsnorm, rmsnorm_decl


# ---------------------------------------------------------------------------
# dense / moe decoder blocks (GQA or MLA attention)
# ---------------------------------------------------------------------------

def decoder_block_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    d = {
        "ln_attn": rmsnorm_decl(cfg.d_model, cfg.dtype),
        "ln_mlp": rmsnorm_decl(cfg.d_model, cfg.dtype),
        "attn": attn.mla_decl(cfg, rules) if cfg.kv_lora_rank else attn.gqa_decl(cfg, rules),
    }
    if cfg.n_experts:
        d["moe"] = moe_mod.moe_decl(cfg, rules)
    else:
        d["mlp"] = mlp_decl(cfg, rules)
    return d


def decoder_block_forward(
    params, x, positions, cfg: ModelConfig, *, window: int | None = None,
    collect_cache: bool = False, rules=None,
):
    """Returns (x, aux_loss) — or (x, aux_loss, cache_entry) when collecting."""
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    cache = None
    if cfg.kv_lora_rank:
        if collect_cache:
            o, (c, kr) = attn.mla_forward(params["attn"], h, positions, cfg, return_cache=True)
            cache = {"c": c, "kr": kr}
        else:
            o = attn.mla_forward(params["attn"], h, positions, cfg)
    else:
        if collect_cache:
            o, (k, v) = attn.gqa_forward(
                params["attn"], h, positions, cfg, window=window, return_kv=True
            )
            cache = {"k": k, "v": v}
        else:
            o = attn.gqa_forward(params["attn"], h, positions, cfg, window=window)
    x = x + o
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    if cfg.n_experts:
        if cfg.moe_ep and rules is not None and getattr(rules, "mesh", None) is not None:
            y, aux = moe_mod.moe_forward_ep(params["moe"], h, cfg, rules)
        else:
            y, aux = moe_mod.moe_forward(params["moe"], h, cfg)
        x = x + y
    else:
        x, aux = x + mlp(params["mlp"], h), jnp.zeros((), jnp.float32)
    if collect_cache:
        return x, aux, cache
    return x, aux


def decoder_block_decode(params, x, cache, pos, cfg: ModelConfig, *, window: int | None = None,
                         rules=None):
    """cache: dict of per-layer tensors. Returns (x, cache)."""
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    if cfg.kv_lora_rank:
        o, c, kr = attn.mla_decode(params["attn"], h, cache["c"], cache["kr"], pos, cfg)
        cache = {"c": c, "kr": kr}
    else:
        o, ck, cv = attn.gqa_decode(
            params["attn"], h, cache["k"], cache["v"], pos, cfg, window=window, rules=rules
        )
        cache = {"k": ck, "v": cv}
    x = x + o
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    if cfg.n_experts:
        y, _ = moe_mod.moe_forward(params["moe"], h, cfg)
        x = x + y
    else:
        x = x + mlp(params["mlp"], h)
    return x, cache


def decoder_cache_decl(cfg: ModelConfig, batch: int, s_cache: int) -> dict:
    """Abstract per-layer cache shapes (dtype = cfg.dtype)."""
    if cfg.kv_lora_rank:
        return {
            "c": (batch, s_cache, cfg.kv_lora_rank),
            "kr": (batch, s_cache, cfg.rope_head_dim),
        }
    return {
        "k": (batch, s_cache, cfg.n_kv_heads, cfg.hd),
        "v": (batch, s_cache, cfg.n_kv_heads, cfg.hd),
    }


# ---------------------------------------------------------------------------
# ssm (mamba2) blocks
# ---------------------------------------------------------------------------

def ssm_block_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    return {"ln": rmsnorm_decl(cfg.d_model, cfg.dtype), "ssm": ssm_mod.ssm_decl(cfg, rules)}


def ssm_block_forward(params, x, cfg: ModelConfig, *, collect_cache: bool = False):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    if collect_cache:
        y, cache = ssm_mod.ssm_forward(params["ssm"], h, cfg, return_state=True)
        return x + y, jnp.zeros((), jnp.float32), cache
    return x + ssm_mod.ssm_forward(params["ssm"], h, cfg), jnp.zeros((), jnp.float32)


def ssm_block_decode(params, x, cache, cfg: ModelConfig):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    y, cache = ssm_mod.ssm_decode(params["ssm"], h, cache, cfg)
    return x + y, cache


def ssm_cache_decl(cfg: ModelConfig, batch: int) -> dict:
    ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": (batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state),
        "conv": (batch, cfg.ssm_conv_width - 1, ch),
    }


# ---------------------------------------------------------------------------
# cross-attention block (VLM)
# ---------------------------------------------------------------------------

def cross_block_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    return {
        "ln_x": rmsnorm_decl(cfg.d_model, cfg.dtype),
        "ln_mlp": rmsnorm_decl(cfg.d_model, cfg.dtype),
        "xattn": attn.cross_attn_decl(cfg, rules),
        "mlp": mlp_decl(cfg, rules),
    }


def cross_block_forward(params, x, img_kv, cfg: ModelConfig):
    h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
    x = x + attn.cross_attn_forward(params["xattn"], h, img_kv, cfg)
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    return x + mlp(params["mlp"], h)
