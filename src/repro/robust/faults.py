"""Fault injection for the federated protocol — the chaos half of ``robust``.

Three fault surfaces, matching where real systems break:

- **Value-level payload corruption** (the batched engine's in-graph plane):
  a message that arrives may arrive *wrong*.  :func:`build_fault_plan` turns
  a :class:`FaultConfig` into jittable per-kind corruptors ``fn(row, key) ->
  row`` applied to the stacked uplinks inside the compiled round/flush —
  bit-flips through ``bitcast``, scaled payloads, sign flips, NaN injection,
  truncated (zero-tail) payloads, each firing per message with the
  configured per-kind probability.  This models what reaches the aggregator
  when frame integrity is NOT checked (or the corruption happened before
  encoding) — the regime robust :mod:`repro.robust.rules` defend.
- **Byzantine clients**: persistent adversaries among the K sources whose
  uplinks are *well-formed but crafted* (sign-flipped, norm-boosted, random,
  or NaN moments/W_RF/classifier rows).  Checksums cannot help here — only
  the aggregation rule can.
- **Byte-level frame corruption** (the serial wire plane):
  :class:`ByteFaultInjector` flips bits in / truncates / replaces the actual
  serialized frames between ``serialize`` and ``deserialize``.  With the
  CRC32 envelope checksum (``comm.wire``) every such frame is *rejected*
  (typed :class:`~repro.comm.wire.WireDecodeError`, never a crash),
  retransmitted up to ``max_retries``, and reported as a drop on give-up —
  the defended regime, where corruption degrades to erasure.

Crash faults (``ServerCrashed`` / ``EdgeCrashed``) live in
``repro.fedsim.events``; the scheduling knobs sit on ``fedsim.AsyncConfig``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

VALUE_MODES = ("bit_flip", "scale", "sign_flip", "nan", "truncate")
BYZANTINE_MODES = ("sign_flip", "scale", "random", "nan")
BYTE_MODES = ("bit_flip", "truncate", "garbage")


@dataclass
class FaultConfig:
    """One knob set for every fault surface (zero rates == no faults at all;
    the trainer then compiles the exact fault-free program, bit-for-bit).

    ``corrupt_*`` are per-uplink corruption probabilities per payload kind;
    ``corruption`` picks the value-level model (``VALUE_MODES``).  On the
    serial wire plane the same rates drive :class:`ByteFaultInjector`
    (byte-level modes; value-only modes fall back to ``bit_flip`` — on a real
    wire every corruption is byte corruption, and the CRC32 checksum turns it
    into reject -> retransmit -> drop).

    ``byzantine`` lists persistent adversarial client ids whose moments /
    W_RF / classifier uplinks are replaced by ``byzantine_mode``-crafted
    payloads every round.
    """

    corrupt_moments: float = 0.0
    corrupt_w_rf: float = 0.0
    corrupt_classifier: float = 0.0
    corruption: str = "bit_flip"
    corruption_scale: float = 100.0  # factor for mode "scale"
    byzantine: tuple[int, ...] = ()
    byzantine_mode: str = "sign_flip"
    byzantine_scale: float = 10.0  # factor for byzantine "scale"/"random"
    max_retries: int = 8  # byte-plane retransmit budget
    seed: int = 0

    def __post_init__(self):
        if self.corruption not in VALUE_MODES:
            raise ValueError(
                f"unknown corruption mode {self.corruption!r}; have {VALUE_MODES}"
            )
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(
                f"unknown byzantine mode {self.byzantine_mode!r}; "
                f"have {BYZANTINE_MODES}"
            )
        for name in ("corrupt_moments", "corrupt_w_rf", "corrupt_classifier"):
            rate = getattr(self, name)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")

    @property
    def rates(self) -> dict[str, float]:
        return {
            "moments": self.corrupt_moments,
            "w_rf": self.corrupt_w_rf,
            "classifier": self.corrupt_classifier,
        }

    @property
    def is_noop(self) -> bool:
        return not self.byzantine and all(r == 0.0 for r in self.rates.values())


# ---------------------------------------------------------------------------
# value-level corruptors (jittable; one row = one message payload)
# ---------------------------------------------------------------------------


def _bit_flip(x, key):
    """Flip one random bit of one random element (f32 bitcast)."""
    flat = x.ravel()
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (), 0, flat.size)
    bit = jax.random.randint(k2, (), 0, 32).astype(jnp.uint32)
    u = jax.lax.bitcast_convert_type(flat.astype(jnp.float32), jnp.uint32)
    u = u.at[idx].set(u[idx] ^ (jnp.uint32(1) << bit))
    return jax.lax.bitcast_convert_type(u, jnp.float32).reshape(x.shape).astype(x.dtype)


def _nan_inject(x, key):
    flat = x.ravel()
    idx = jax.random.randint(key, (), 0, flat.size)
    return flat.at[idx].set(jnp.nan).reshape(x.shape)


def _truncate(x, key):
    """Zero the payload's tail from a random offset (a frame cut mid-flight,
    decoded anyway because nobody checked integrity)."""
    flat = x.ravel()
    off = jax.random.randint(key, (), 1, flat.size)
    return jnp.where(jnp.arange(flat.size) < off, flat, 0.0).reshape(x.shape)


def make_corruptor(mode: str, rate: float, scale: float):
    """Jittable ``fn(row, key) -> row`` corrupting with probability ``rate``."""
    if mode == "bit_flip":
        hit = _bit_flip
    elif mode == "scale":
        hit = lambda x, k: x * scale
    elif mode == "sign_flip":
        hit = lambda x, k: -x
    elif mode == "nan":
        hit = _nan_inject
    elif mode == "truncate":
        hit = _truncate
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")

    def corrupt(x, key):
        k_gate, k_hit = jax.random.split(key)
        do = jax.random.bernoulli(k_gate, rate)
        return jnp.where(do, hit(x, k_hit), x)

    return corrupt


def make_byzantine_craft(mode: str, scale: float):
    """Jittable ``fn(row, key) -> row`` replacing an honest payload by the
    adversary's crafted one."""
    if mode == "sign_flip":
        return lambda x, k: -x  # the classic gradient-reversal attack
    if mode == "scale":
        return lambda x, k: x * scale  # model boosting
    if mode == "nan":
        return lambda x, k: jnp.full_like(x, jnp.nan)
    if mode == "random":

        def craft(x, key):
            noise = jax.random.normal(key, x.shape, x.dtype)
            norm = jnp.linalg.norm(x.ravel())
            return noise * (scale * norm / jnp.maximum(jnp.linalg.norm(noise.ravel()), 1e-12))

        return craft
    raise ValueError(f"unknown byzantine mode {mode!r}")


@dataclass
class FaultPlan:
    """Engine-facing compiled fault surface: per-kind corruptors + the
    Byzantine mask/craft.  Built by :func:`build_fault_plan`; ``None`` when
    the config is a no-op so the fault-free program stays bit-identical."""

    corruptors: dict = field(default_factory=dict)  # kind -> fn(row, key) -> row
    byz_mask: jnp.ndarray | None = None  # (K,) 0/1 floats
    craft: object | None = None  # fn(row, key) -> row

    def apply(self, kind: str, rows: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """Per-client fault pass over stacked (K, ...) uplink payloads:
        Byzantine rows are replaced by crafted ones, then the channel
        corruption fires per message.  One key per client, shared between
        the craft and the corruption gate of the same message."""
        keys = jax.random.split(key, rows.shape[0])
        if self.byz_mask is not None:
            crafted = jax.vmap(self.craft)(rows, keys)
            sel = self.byz_mask.reshape((-1,) + (1,) * (rows.ndim - 1))
            rows = jnp.where(sel > 0, crafted, rows)
        fn = self.corruptors.get(kind)
        if fn is not None:
            rows = jax.vmap(fn)(rows, jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys))
        return rows


def build_fault_plan(cfg: FaultConfig | None, k: int) -> FaultPlan | None:
    """FaultConfig -> FaultPlan for a K-client stacked engine (None if no-op)."""
    if cfg is None or cfg.is_noop:
        return None
    bad = [i for i in cfg.byzantine if not 0 <= i < k]
    if bad:
        raise ValueError(f"byzantine ids {bad} out of range for K={k}")
    corruptors = {
        kind: make_corruptor(cfg.corruption, rate, cfg.corruption_scale)
        for kind, rate in cfg.rates.items()
        if rate > 0.0
    }
    byz_mask, craft = None, None
    if cfg.byzantine:
        m = np.zeros((k,), np.float32)
        m[list(cfg.byzantine)] = 1.0
        byz_mask = jnp.asarray(m)
        craft = make_byzantine_craft(cfg.byzantine_mode, cfg.byzantine_scale)
    return FaultPlan(corruptors=corruptors, byz_mask=byz_mask, craft=craft)


# ---------------------------------------------------------------------------
# byte-level frame corruption (the serial wire plane)
# ---------------------------------------------------------------------------


@dataclass
class ByteFaultInjector:
    """Corrupts serialized frames between serialize and deserialize.

    ``rates`` maps payload kind -> per-frame corruption probability; every
    corrupted frame fails the CRC32 envelope check and surfaces as a typed
    ``WireDecodeError`` the transport turns into reject -> retransmit ->
    (after ``max_retries``) drop.
    """

    rates: dict[str, float] = field(default_factory=dict)
    mode: str = "bit_flip"
    max_retries: int = 8
    seed: int = 0

    def __post_init__(self):
        if self.mode not in BYTE_MODES:
            raise ValueError(f"unknown byte mode {self.mode!r}; have {BYTE_MODES}")
        self._rng = np.random.default_rng(self.seed)

    @classmethod
    def from_config(cls, cfg: FaultConfig) -> "ByteFaultInjector":
        mode = cfg.corruption if cfg.corruption in BYTE_MODES else "bit_flip"
        return cls(
            rates=dict(cfg.rates), mode=mode, max_retries=cfg.max_retries,
            seed=cfg.seed,
        )

    def corrupt(self, kind: str, data: bytes) -> bytes:
        rate = self.rates.get(kind, 0.0)
        if rate <= 0.0 or self._rng.random() >= rate:
            return data
        buf = bytearray(data)
        if self.mode == "bit_flip":
            i = int(self._rng.integers(len(buf)))
            buf[i] ^= 1 << int(self._rng.integers(8))
            return bytes(buf)
        if self.mode == "truncate":
            return bytes(buf[: int(self._rng.integers(1, max(len(buf), 2)))])
        return self._rng.integers(0, 256, size=len(buf), dtype=np.uint8).tobytes()
