"""Fault injection and Byzantine-robust defenses for FedRF-TCA.

``rules`` — the :class:`AggregationRule` seam (mean / finite_mean /
norm_clip / trimmed_mean / geomedian), all in-graph.  ``faults`` — the
chaos side: value-level payload corruption + Byzantine client plans for the
batched engine, byte-level frame corruption for the serial wire plane.
"""
from repro.robust.faults import (
    BYTE_MODES,
    BYZANTINE_MODES,
    VALUE_MODES,
    ByteFaultInjector,
    FaultConfig,
    FaultPlan,
    build_fault_plan,
    make_byzantine_craft,
    make_corruptor,
)
from repro.robust.rules import (
    AggregationRule,
    FiniteMeanRule,
    GeoMedianRule,
    MeanRule,
    NormClipRule,
    TrimmedMeanRule,
    finite_guard,
    get_rule,
    rule_names,
)

__all__ = [
    "AggregationRule",
    "BYTE_MODES",
    "BYZANTINE_MODES",
    "ByteFaultInjector",
    "FaultConfig",
    "FaultPlan",
    "FiniteMeanRule",
    "GeoMedianRule",
    "MeanRule",
    "NormClipRule",
    "TrimmedMeanRule",
    "VALUE_MODES",
    "build_fault_plan",
    "finite_guard",
    "get_rule",
    "make_byzantine_craft",
    "make_corruptor",
    "rule_names",
]
