"""Byzantine-robust aggregation rules — the ``AggregationRule`` seam.

Every FedRF-TCA aggregate is a weighted sum over client payloads (moments,
W_RF, classifier leaves) divided by a mass.  The exact-union merge this repo
shipped until now is therefore maximally fragile: a single corrupted or
adversarial uplink enters the pooled sum with full weight and poisons the
global model exactly.  An :class:`AggregationRule` owns that one contraction
— ``weighted_sum(values (K, ...), weights (K,)) -> (sum (...), mass ())`` —
so swapping the merge estimator never touches the protocol around it, and
every rule runs **in-graph** (pure jnp, jit/vmap-safe): the batched round and
the async flush stay one compiled dispatch each.

Rules (``get_rule("name[:param]")``):

==================  =========================================================
``mean``            the seed's exact weighted sum (``einsum`` contraction) —
                    bit-for-bit today's pipeline, no finite guard (NaNs
                    propagate, which is exactly the fragility the robust
                    rules fix)
``finite_mean``     mean + finite-guard quarantine: rows containing any
                    NaN/Inf entry get weight 0 and value 0 (0 * NaN would
                    still poison the sum)
``norm_clip[:c]``   each row scaled to L2 norm <= c before the mean; with no
                    ``c`` the clip radius is the median norm of the delivered
                    rows (scale-free).  Bounds any single row's pull.
``trimmed_mean[:b]``coordinate-wise weighted trimmed mean discarding the
                    ``b`` (default 0.2) weight-fraction tails per coordinate
                    — breakdown point b (f < b*K arbitrary rows cannot move
                    any coordinate outside the honest range)
``geomedian[:it]``  smoothed geometric median via ``it`` (default 8)
                    Weiszfeld iterations — the classic high-dimension robust
                    location estimate (breakdown 1/2)
==================  =========================================================

All rules except ``mean`` apply the finite guard first, so a NaN-injected
update is quarantined rather than averaged.  Every rule reports the *raw*
delivered mass alongside its estimate (``sum = estimate * mass``), so the
downstream ``(sum + target) / (mass + 1)`` and ``sum / mass`` consumers are
rule-agnostic.

:meth:`AggregationRule.merge_moments` is the second seam: the target's
per-pair MMD consumes a *stack* of moment messages with weights, and the mean
rule must leave that stack untouched (bitwise degeneracy).  Robust rules
instead collapse it to the single robust pooled moment carrying the total
mass — the same estimator family the two-tier fleet plane already uses for
per-edge pooled moments.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def finite_guard(values: jnp.ndarray, weights: jnp.ndarray):
    """Quarantine non-finite rows: weight 0 AND value 0 (so ``0 * NaN`` can
    never leak back into a sum).  values: (K, ...), weights: (K,)."""
    flat = values.reshape(values.shape[0], -1)
    ok = jnp.all(jnp.isfinite(flat), axis=1)
    shaped = ok.reshape((-1,) + (1,) * (values.ndim - 1))
    return jnp.where(shaped, values, 0.0), weights * ok.astype(weights.dtype)


class AggregationRule:
    """One merge estimator: a weighted sum + the mass it represents."""

    name: str = ""
    is_mean: bool = False  # True only for the bitwise-degenerate seed rule

    def weighted_sum(self, values: jnp.ndarray, weights: jnp.ndarray):
        """(K, ...) values x (K,) weights -> ((...) sum, () mass).

        ``sum`` plays the role of the seed's ``einsum(w, v)`` contraction:
        consumers divide by ``mass`` (or ``mass + 1`` with a server term).
        Robust rules return ``estimate * mass`` so that division recovers
        the robust estimate.
        """
        raise NotImplementedError

    def estimate(self, values: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
        """The robust weighted mean itself (sum / mass, mass-guarded)."""
        s, m = self.weighted_sum(values, weights)
        return s / jnp.maximum(m, _EPS)

    def merge_moments(self, msgs: jnp.ndarray, weights: jnp.ndarray):
        """(K, 2N) moment stack + (K,) weights -> (stack, weights) the target
        trains on.  Mean: identity (the seed's per-pair MMD over per-client
        messages).  Robust rules: the single pooled robust moment row with
        the total delivered mass."""
        s, m = self.weighted_sum(msgs, weights)
        pooled = s / jnp.maximum(m, _EPS)
        return pooled[None, :], m[None]

    def attribution(self, values: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
        """Per-row trim/quarantine indicator in [0, 1] — the health probe.

        (K, ...) values x (K,) weights -> (K,): how much of row k this rule
        discounted.  0 = fully trusted (or not delivered — absent rows are
        the transport's business, not the rule's), 1 = fully quarantined /
        trimmed away.  Runs in-graph next to :meth:`weighted_sum` so the
        probe adds outputs, never dispatches.  The mean rule discounts
        nothing by construction.
        """
        return jnp.zeros(values.shape[0], dtype=values.dtype)


class MeanRule(AggregationRule):
    """The seed's exact-union weighted mean — bitwise today's pipeline."""

    name, is_mean = "mean", True

    def weighted_sum(self, values, weights):
        # the literal seed contraction ("k,kij->ij" for W_RF, tensordot for
        # classifier leaves): einsum with ellipsis is bitwise-equal to both
        return jnp.einsum("k,k...->...", weights, values), jnp.sum(weights)

    def merge_moments(self, msgs, weights):
        return msgs, weights  # untouched: bitwise the seed target loss


class FiniteMeanRule(AggregationRule):
    """Weighted mean with NaN/Inf rows quarantined (weight + value zeroed)."""

    name = "finite_mean"

    def weighted_sum(self, values, weights):
        values, weights = finite_guard(values, weights)
        return jnp.einsum("k,k...->...", weights, values), jnp.sum(weights)

    def attribution(self, values, weights):
        flat = values.reshape(values.shape[0], -1)
        bad = jnp.any(~jnp.isfinite(flat), axis=1)
        # only delivered rows can be *quarantined* — weight-0 rows were
        # never candidates for the sum in the first place
        return (bad & (weights > 0)).astype(flat.dtype)


class NormClipRule(AggregationRule):
    """Mean of rows clipped to L2 norm <= ``clip`` (median-norm when None).

    Clipping values, not weights: an adversarial row still votes, but its
    pull is bounded by the clip radius — the standard defense against scaled
    (model-boosting) attacks.
    """

    name = "norm_clip"

    def __init__(self, clip: float | None = None):
        self.clip = clip
        if clip is not None:
            self.name = f"norm_clip:{clip:g}"

    def weighted_sum(self, values, weights):
        values, weights = finite_guard(values, weights)
        flat = values.reshape(values.shape[0], -1)
        norms = jnp.linalg.norm(flat, axis=1)
        if self.clip is None:
            # median norm over delivered rows (undelivered rows pushed to
            # +inf so they never define the radius); all-dropped -> radius 0
            masked = jnp.where(weights > 0, norms, jnp.inf)
            order = jnp.sort(masked)
            n_live = jnp.sum(weights > 0).astype(jnp.int32)
            mid = jnp.maximum(n_live - 1, 0) // 2
            radius = jnp.where(n_live > 0, order[mid], 0.0)
        else:
            radius = jnp.asarray(self.clip, flat.dtype)
        scale = jnp.minimum(1.0, radius / jnp.maximum(norms, _EPS))
        clipped = flat * scale[:, None]
        s = jnp.einsum("k,kd->d", weights, clipped)
        return s.reshape(values.shape[1:]), jnp.sum(weights)

    def attribution(self, values, weights):
        raw, guarded = finite_guard(values, weights)
        flat = raw.reshape(raw.shape[0], -1)
        norms = jnp.linalg.norm(flat, axis=1)
        if self.clip is None:
            masked = jnp.where(guarded > 0, norms, jnp.inf)
            order = jnp.sort(masked)
            n_live = jnp.sum(guarded > 0).astype(jnp.int32)
            mid = jnp.maximum(n_live - 1, 0) // 2
            radius = jnp.where(n_live > 0, order[mid], 0.0)
        else:
            radius = jnp.asarray(self.clip, flat.dtype)
        scale = jnp.minimum(1.0, radius / jnp.maximum(norms, _EPS))
        # fraction of the row's norm clipped away; quarantined rows score 1
        trimmed = (1.0 - scale) * (guarded > 0)
        quarantined = (weights > 0) & (guarded <= 0)
        return jnp.where(quarantined, 1.0, trimmed) * (weights > 0)


class TrimmedMeanRule(AggregationRule):
    """Coordinate-wise weighted trimmed mean (trim fraction ``beta`` per tail).

    Exact interval trimming on the weight axis: per coordinate the rows are
    sorted by value, and each row contributes the overlap of its cumulative-
    weight interval with ``[beta * W, (1 - beta) * W]`` — so weight-0
    (undelivered / quarantined) rows occupy no quantile mass, and ``beta=0``
    recovers the weighted mean exactly.  ``f`` arbitrary rows of total weight
    ``< beta * W`` cannot move any coordinate outside the honest value range
    (the breakdown property the hypothesis tests pin).
    """

    name = "trimmed_mean"

    def __init__(self, beta: float = 0.2):
        if not 0.0 <= beta < 0.5:
            raise ValueError(f"trim fraction must be in [0, 0.5), got {beta}")
        self.beta = beta
        self.name = f"trimmed_mean:{beta:g}"

    def weighted_sum(self, values, weights):
        values, weights = finite_guard(values, weights)
        flat = values.reshape(values.shape[0], -1)  # (K, D)
        order = jnp.argsort(flat, axis=0)  # (K, D) row order per coordinate
        v_s = jnp.take_along_axis(flat, order, axis=0)
        w_s = weights[order]  # (K, D) weights in value order
        cw = jnp.cumsum(w_s, axis=0)
        total = cw[-1]  # (D,) == sum(weights) everywhere
        lo, hi = self.beta * total, (1.0 - self.beta) * total
        eff = jnp.clip(jnp.minimum(cw, hi) - jnp.maximum(cw - w_s, lo), 0.0, None)
        est = jnp.sum(eff * v_s, axis=0) / jnp.maximum(jnp.sum(eff, axis=0), _EPS)
        mass = jnp.sum(weights)
        return (est * mass).reshape(values.shape[1:]), mass

    def attribution(self, values, weights):
        raw_w = weights
        values, weights = finite_guard(values, weights)
        flat = values.reshape(values.shape[0], -1)  # (K, D)
        order = jnp.argsort(flat, axis=0)
        w_s = weights[order]
        cw = jnp.cumsum(w_s, axis=0)
        total = cw[-1]
        lo, hi = self.beta * total, (1.0 - self.beta) * total
        eff = jnp.clip(jnp.minimum(cw, hi) - jnp.maximum(cw - w_s, lo), 0.0, None)
        # scatter per-coordinate retained weight back to original row order
        inv = jnp.argsort(order, axis=0)
        eff_orig = jnp.take_along_axis(eff, inv, axis=0)  # (K, D)
        d = flat.shape[1]
        retained = jnp.sum(eff_orig, axis=1) / jnp.maximum(weights * d, _EPS)
        trimmed = (1.0 - jnp.clip(retained, 0.0, 1.0)) * (weights > 0)
        quarantined = (raw_w > 0) & (weights <= 0)
        return jnp.where(quarantined, 1.0, trimmed) * (raw_w > 0)


class GeoMedianRule(AggregationRule):
    """Smoothed geometric median (Weiszfeld iterations, fixed count).

    Iteratively reweighted mean ``b <- sum_k (w_k / max(||v_k - b||, eps)) v_k
    / sum_k (...)`` starting from the weighted mean; a fixed iteration count
    keeps the program jittable and the cost deterministic.  Arbitrarily
    large adversarial rows get arbitrarily small Weiszfeld weights, so the
    estimate stays near the honest majority (breakdown point 1/2).
    """

    name = "geomedian"

    def __init__(self, iters: int = 8):
        if iters < 1:
            raise ValueError(f"need >= 1 Weiszfeld iteration, got {iters}")
        self.iters = int(iters)
        self.name = f"geomedian:{self.iters}"

    def weighted_sum(self, values, weights):
        values, weights = finite_guard(values, weights)
        flat = values.reshape(values.shape[0], -1)
        mass = jnp.sum(weights)
        b = jnp.einsum("k,kd->d", weights, flat) / jnp.maximum(mass, _EPS)
        for _ in range(self.iters):
            d = jnp.linalg.norm(flat - b[None, :], axis=1)
            wz = weights / jnp.maximum(d, 1e-6)
            b = jnp.einsum("k,kd->d", wz, flat) / jnp.maximum(jnp.sum(wz), _EPS)
        return (b * mass).reshape(values.shape[1:]), mass

    def attribution(self, values, weights):
        raw_w = weights
        values, weights = finite_guard(values, weights)
        flat = values.reshape(values.shape[0], -1)
        mass = jnp.sum(weights)
        b = jnp.einsum("k,kd->d", weights, flat) / jnp.maximum(mass, _EPS)
        for _ in range(self.iters):
            d = jnp.linalg.norm(flat - b[None, :], axis=1)
            wz = weights / jnp.maximum(d, 1e-6)
            b = jnp.einsum("k,kd->d", wz, flat) / jnp.maximum(jnp.sum(wz), _EPS)
        # outlyingness relative to the worst delivered row: the median's
        # implicit downweighting is 1/distance, so distance itself is the
        # natural "how much was this row ignored" signal
        d = jnp.linalg.norm(flat - b[None, :], axis=1)
        d = d * (weights > 0)
        rel = d / jnp.maximum(jnp.max(d), _EPS)
        quarantined = (raw_w > 0) & (weights <= 0)
        return jnp.where(quarantined, 1.0, rel) * (raw_w > 0)


_FACTORIES = {
    "mean": MeanRule,
    "finite_mean": FiniteMeanRule,
    "norm_clip": NormClipRule,
    "trimmed_mean": TrimmedMeanRule,
    "geomedian": lambda p=8: GeoMedianRule(int(p)),
}


def rule_names() -> list[str]:
    return sorted(_FACTORIES)


def get_rule(spec) -> AggregationRule:
    """``get_rule("trimmed_mean:0.25")`` — name[:param]; rule instances pass
    through (custom rules plug into the same seam)."""
    if isinstance(spec, AggregationRule):
        return spec
    name, _, param = str(spec).partition(":")
    if name not in _FACTORIES:
        raise ValueError(f"unknown aggregation rule {spec!r}; have {rule_names()}")
    return _FACTORIES[name](float(param)) if param else _FACTORIES[name]()
